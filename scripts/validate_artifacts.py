#!/usr/bin/env python
"""Validate committed benchmark artifacts: parseable JSON + schema-sane.

A torn write to ``benchmarks/artifacts/*.json`` (the tuning cache is
written concurrently by test runs) or a stale ``BENCH_serving.json``
otherwise surfaces much later as a confusing downstream failure; this
fails the check gate in milliseconds instead. Runs standalone:

    python scripts/validate_artifacts.py        (also part of make check)
"""

from __future__ import annotations

import glob
import json
import math
import numbers
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FAILURES: list = []


def fail(path: str, msg: str) -> None:
    FAILURES.append(f"{os.path.relpath(path, REPO)}: {msg}")


def load(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        fail(path, f"unreadable/torn JSON ({e})")
        return None


def require(path: str, obj, dotted: str, kind=numbers.Real) -> None:
    cur = obj
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            fail(path, f"missing key {dotted!r}")
            return
        cur = cur[part]
    if not isinstance(cur, kind):
        fail(path, f"{dotted!r} is {type(cur).__name__}, want "
                   f"{getattr(kind, '__name__', kind)}")


def check_tuning_cache(path: str) -> None:
    obj = load(path)
    if obj is None:
        return
    if not isinstance(obj, dict):
        return fail(path, f"root is {type(obj).__name__}, want object")
    for key, entry in obj.items():
        if key.startswith("serve_measured:"):
            # Measured serving spans (serve/telemetry.py drift gate):
            # no kernel block geometry, just a positive wall time.
            if not (isinstance(entry, dict)
                    and isinstance(entry.get("time_s"), numbers.Real)
                    and entry["time_s"] > 0):
                fail(path, f"implausible measurement {key!r}")
            continue
        if key.startswith("calibrated:"):
            # Probed serving-path constants (core/calibrate.py):
            # schema-versioned, finite positive value, probe metadata.
            if not (isinstance(entry, dict)
                    and entry.get("schema_version") == 1
                    and isinstance(entry.get("value"), numbers.Real)
                    and math.isfinite(entry["value"])
                    and entry["value"] > 0
                    and isinstance(entry.get("n_trials"), int)
                    and entry["n_trials"] > 0
                    and isinstance(entry.get("backend"), str)
                    and isinstance(entry.get("mesh"), str)):
                fail(path, f"malformed calibration entry {key!r}")
            continue
        if not isinstance(entry, dict) or not {
                "block_q", "block_k", "time_s", "terms"} <= set(entry):
            fail(path, f"malformed entry {key!r}")
        elif not (isinstance(entry["block_q"], int)
                  and isinstance(entry["block_k"], int)
                  and isinstance(entry["time_s"], numbers.Real)
                  and entry["time_s"] > 0):
            fail(path, f"implausible entry {key!r}")


def check_dryrun_baseline(path: str) -> None:
    obj = load(path)
    if obj is None:
        return
    cells = obj.get("cells") if isinstance(obj, dict) else obj
    if not isinstance(cells, (list, dict)) or not cells:
        return fail(path, "no cells")


def check_bench_serving(path: str) -> None:
    obj = load(path)
    if obj is None:
        return
    before = len(FAILURES)       # range checks gate on *this* file only
    for dotted in ("measured.tokens_per_s", "measured.cache_hbm_rows",
                   "measured.paged.tokens_per_s", "measured.paged_rows_ratio",
                   "measured.paged.prefill_executables",
                   "measured.paged.prefill_chunk",
                   "modeled_decode_32k.speedup",
                   "paged_decode_32k.reservation_ratio",
                   "paged_decode_32k.tokens_per_s_paged",
                   "paged_decode_32k.lookup_overhead_frac",
                   "prefill_chunked_interleave.decode_tokens_during_prefill",
                   "prefill_chunked_interleave.prefill_chunks",
                   "prefill_chunked_interleave.prefill_executables",
                   "prefill_chunked_32k.chunk",
                   "prefill_chunked_32k.prefill_s",
                   "prefill_chunked_32k.interleave_latency_s",
                   "prefill_chunked_32k.latency_reduction",
                   "prefill_chunked_32k.prefill_overhead_frac",
                   "spec_decode_accept.spec_k",
                   "spec_decode_accept.accepted_per_tick",
                   "spec_decode_accept.emitted_per_tick",
                   "spec_decode_accept.accept_rate",
                   "spec_decode_accept.verify_executables",
                   "spec_decode_accept.verify_ticks",
                   "spec_decode_32k.chosen_k",
                   "spec_decode_32k.accept_rate",
                   "spec_decode_32k.expected_tokens_per_tick",
                   "spec_decode_32k.speedup",
                   "spec_decode_32k.verify_overhead_frac",
                   "spec_decode_32k.k_at_low_accept_model_draft",
                   "prefix_cache_hit.sharers",
                   "prefix_cache_hit.prefix_hits",
                   "prefix_cache_hit.hit_pages",
                   "prefix_cache_hit.ttft_ticks_uncached",
                   "prefix_cache_hit.ttft_ticks_hit",
                   "prefix_cache_hit.ttft_reduction",
                   "prefix_cache_hit.reservation_ratio",
                   "prefix_cache_32k.hit_rate",
                   "prefix_cache_32k.prefill_s_off",
                   "prefix_cache_32k.prefill_s_hit",
                   "prefix_cache_32k.probe_s",
                   "prefix_cache_32k.cow_s",
                   "prefix_cache_32k.speedup",
                   "prefix_cache_32k.ttft_frac_hit",
                   "tp_pool_capacity.n_devices",
                   "tp_pool_capacity.capacity_1dev",
                   "tp_pool_capacity.capacity_tp",
                   "tp_pool_capacity.max_device_span",
                   "tp_pool_capacity.decode_executables_1dev",
                   "tp_pool_capacity.decode_executables_tp",
                   "tp_decode_32k.n_devices",
                   "tp_decode_32k.speedup",
                   "tp_decode_32k.collective_s",
                   "tp_decode_32k.collective_frac",
                   "tp_decode_32k.pool_capacity_ratio",
                   "breaking_point_sweep.knee_rate",
                   "breaking_point_sweep.knee_goodput_tokens_per_tick",
                   "breaking_point_faults.faults_injected",
                   "breaking_point_faults.faults_cleared",
                   "breaking_point_faults.unresolved",
                   "breaking_point_faults.streams_compared",
                   "breaking_point_faults.shed_rate",
                   "breaking_point_faults.spec_probes",
                   "breaking_point_faults.pool_pages_leaked",
                   "telemetry_overhead.traced_wall_s",
                   "telemetry_overhead.untraced_wall_s",
                   "telemetry_overhead.overhead_ratio",
                   "telemetry_overhead.repeats",
                   "telemetry_overhead.trace_events",
                   "model_vs_measured.schema_version",
                   "model_vs_measured.decode.measured_s",
                   "model_vs_measured.decode.modeled_s",
                   "model_vs_measured.decode.ratio",
                   "model_vs_measured.prefill_chunk.measured_s",
                   "model_vs_measured.prefill_chunk.modeled_s",
                   "model_vs_measured.prefill_chunk.ratio",
                   "model_vs_measured.spec_verify.measured_s",
                   "model_vs_measured.spec_verify.modeled_s",
                   "model_vs_measured.spec_verify.ratio",
                   "calibration_probes.schema_version",
                   "calibration_probes.n_measured"):
        require(path, obj, dotted)
    require(path, obj, "calibration_probes.backend", str)
    require(path, obj, "calibration_probes.resolved_source", str)
    require(path, obj, "calibration_probes.constants", dict)
    require(path, obj, "prefix_cache_hit.stream_parity", bool)
    require(path, obj, "prefix_cache_hit.counters_reconcile", bool)
    require(path, obj, "prefix_cache_32k.enabled", bool)
    require(path, obj, "prefix_cache_32k.enabled_at_zero_hit_rate", bool)
    require(path, obj, "tp_pool_capacity.parity", bool)
    require(path, obj, "breaking_point_faults.parity", bool)
    require(path, obj, "breaking_point_sweep.offered_rates", list)
    require(path, obj, "breaking_point_sweep.points", list)
    require(path, obj, "telemetry_overhead.parity", bool)
    if len(FAILURES) == before:
        if not obj["modeled_decode_32k"]["speedup"] > 1.0:
            fail(path, "flash-decode speedup <= 1")
        if not 0 < obj["paged_decode_32k"]["reservation_ratio"] < 0.5:
            fail(path, "paged reservation_ratio not in (0, 0.5)")
        # Chunked-prefill acceptance: one executable for every
        # prompt-length mix, decode progress mid-prefill, and a chunk
        # that actually buys interleave latency back.
        if obj["measured"]["paged"]["prefill_executables"] != 1:
            fail(path, "chunked paged prefill compiled != 1 executable")
        if obj["prefill_chunked_interleave"]["prefill_executables"] != 1:
            fail(path, "interleave cell compiled != 1 prefill executable")
        if not obj["prefill_chunked_interleave"][
                "decode_tokens_during_prefill"] > 0:
            fail(path, "no decode tokens landed during long-prompt prefill")
        if not obj["prefill_chunked_32k"]["latency_reduction"] > 1.0:
            fail(path, "chunked prefill latency_reduction <= 1")
        # Speculative-decoding acceptance: accept rates are rates, the
        # verify path traced exactly one executable, the measured n-gram
        # cell beats one accepted draft per tick, and the modeled cell
        # both speculates profitably and knows when to disable (k=0).
        for cell in ("spec_decode_accept", "spec_decode_32k"):
            if not 0.0 <= obj[cell]["accept_rate"] <= 1.0:
                fail(path, f"{cell}.accept_rate outside [0, 1]")
        if obj["spec_decode_accept"]["verify_executables"] != 1:
            fail(path, "spec verify compiled != 1 executable")
        if not obj["spec_decode_accept"]["accepted_per_tick"] > 1.0:
            fail(path, "n-gram drafter accepted <= 1 token per verify tick")
        if not obj["spec_decode_32k"]["speedup"] > 1.0:
            fail(path, "modeled spec decode speedup <= 1")
        if obj["spec_decode_32k"]["k_at_low_accept_model_draft"] != 0:
            fail(path, "choose_spec_k failed to disable at low accept")
        # Prefix-cache acceptance: cached streams are bit-identical
        # (parity *asserted*), >= 2 concurrent sharers saw suffix-only
        # TTFT strictly below the uncached engine, the shared pool's
        # high water sat strictly below it too, the hit/COW counters
        # reconciled with the allocator, and the modeled cell enables
        # profitably at 60% hit rate while disabling at hit rate 0.
        pfx = obj["prefix_cache_hit"]
        if pfx["stream_parity"] is not True:
            fail(path, "prefix-cached streams diverged from uncached")
        if pfx["sharers"] < 2 or pfx["prefix_hits"] < 2:
            fail(path, "prefix cell ran < 2 sharers / hits")
        if not pfx["ttft_ticks_hit"] < pfx["ttft_ticks_uncached"]:
            fail(path, "cached TTFT not below uncached")
        if not 0 < pfx["reservation_ratio"] < 1.0:
            fail(path, "shared-pool reservation not below uncached")
        if pfx["counters_reconcile"] is not True:
            fail(path, "hit/COW telemetry out of sync with allocator")
        pfk = obj["prefix_cache_32k"]
        if pfk["enabled"] is not True or not pfk["speedup"] > 1.0:
            fail(path, "choose_prefix_cache not profitable at hit=0.6")
        if pfk["enabled_at_zero_hit_rate"] is not False:
            fail(path, "choose_prefix_cache failed to disable at hit=0")
        if not 0.0 < pfk["ttft_frac_hit"] < 1.0:
            fail(path, "ttft_frac_hit outside (0, 1)")
        # Distributed-serving acceptance: the mesh engine's streams are
        # bit-identical (parity flag *asserted*, not assumed), a slot's
        # context spans >= 2 devices, same n_pages -> same capacity on
        # either mesh, and exactly one decode executable per mesh.
        tp = obj["tp_pool_capacity"]
        if tp["parity"] is not True:
            fail(path, "tp engine streams diverged from single-device")
        if tp["max_device_span"] < 2:
            fail(path, "no slot's page table spanned >= 2 devices")
        if tp["capacity_tp"] != tp["capacity_1dev"]:
            fail(path, "device-sharded pool changed global capacity")
        if tp["decode_executables_tp"] != 1 or \
                tp["decode_executables_1dev"] != 1:
            fail(path, "decode compiled != 1 executable per mesh")
        if not obj["tp_decode_32k"]["speedup"] > 1.0:
            fail(path, "modeled tp decode speedup <= 1")
        if obj["tp_decode_32k"]["pool_capacity_ratio"] != \
                obj["tp_decode_32k"]["n_devices"]:
            fail(path, "pool capacity ratio != mesh degree")
        # Breaking-point acceptance: the sweep found a knee and the
        # latency surface is sane (ordered percentiles, shed is a rate,
        # goodput monotone non-increasing past saturation), and the
        # canonical fault schedule left zero hangs, zero leaked pages,
        # and bit-identical surviving streams.
        bp = obj["breaking_point_sweep"]
        pts = bp["points"]
        if not pts:
            fail(path, "breaking-point sweep has no points")
        elif bp["knee_rate"] not in bp["offered_rates"]:
            fail(path, "knee_rate not one of the swept offered rates")
        else:
            for p in pts:
                if p["ttft_p99"] < p["ttft_p50"] or \
                        p["tpot_p99"] < p["tpot_p50"]:
                    fail(path, "latency percentiles out of order")
                if not 0.0 <= p["shed_rate"] <= 1.0:
                    fail(path, "shed_rate outside [0, 1]")
            knee_i = bp["offered_rates"].index(bp["knee_rate"])
            for a, b in zip(pts[knee_i:], pts[knee_i + 1:]):
                if b["goodput_tokens_per_tick"] > \
                        a["goodput_tokens_per_tick"] * 1.05:
                    fail(path, "goodput rose past the knee (not saturated)")
        bf = obj["breaking_point_faults"]
        if bf["unresolved"] != 0:
            fail(path, "fault schedule left unresolved requests")
        if bf["parity"] is not True:
            fail(path, "faulted streams diverged from fault-free engine")
        if not bf["faults_injected"] == bf["faults_cleared"] == 3:
            fail(path, "canonical schedule did not arm+clear all 3 faults")
        if bf["pool_pages_leaked"] != 0:
            fail(path, "fault run leaked pool pages")
        # Telemetry acceptance: tracing is observational — identical
        # token streams and < 5% wall overhead on the smoke workload —
        # and the drift gate actually *measured* every component (a
        # ratio of 0 is the never-measured sentinel; wall clocks are
        # host-dependent so magnitude is not gated, presence is).
        to = obj["telemetry_overhead"]
        if to["parity"] is not True:
            fail(path, "tracing changed the token stream")
        if not 0 < to["overhead_ratio"] < 1.05:
            fail(path, "telemetry overhead_ratio not in (0, 1.05)")
        if not to["trace_events"] > 0:
            fail(path, "traced run recorded no events")
        for comp in ("decode", "prefill_chunk", "spec_verify"):
            row = obj["model_vs_measured"][comp]
            for k in ("measured_s", "modeled_s", "ratio"):
                if not (math.isfinite(row[k]) and row[k] > 0):
                    fail(path, f"model_vs_measured.{comp}.{k} "
                               f"not finite/positive")
        # Calibration acceptance: >= 5 constants actually measured
        # (finite positive values with a recorded measured-vs-default
        # drift ratio), and the pass left resolve_constants preferring
        # the calibrated set. Magnitudes are host-dependent; presence
        # and sanity are what's gated.
        cal = obj["calibration_probes"]
        if cal["schema_version"] != 1:
            fail(path, "calibration_probes.schema_version != 1")
        if cal["n_measured"] < 5:
            fail(path, "calibration pass measured < 5 constants")
        if cal["resolved_source"] != "calibrated":
            fail(path, "calibration did not become the resolved set")
        for name, row in cal["constants"].items():
            if not isinstance(row, dict):
                fail(path, f"calibration_probes.constants.{name} "
                           f"not an object")
                continue
            for k in ("measured", "assumed", "drift_ratio"):
                v = row.get(k)
                if not (isinstance(v, numbers.Real)
                        and math.isfinite(v) and v > 0):
                    fail(path, f"calibration_probes.constants."
                               f"{name}.{k} not finite/positive")


SPECIFIC = {
    "attn_tuning_cache.json": check_tuning_cache,
    "dryrun_baseline.json": check_dryrun_baseline,
}


def main() -> int:
    seen = 0
    for path in sorted(glob.glob(
            os.path.join(REPO, "benchmarks", "artifacts", "*.json"))):
        seen += 1
        SPECIFIC.get(os.path.basename(path), load)(path)
    bench = os.path.join(REPO, "BENCH_serving.json")
    if os.path.exists(bench):
        seen += 1
        check_bench_serving(bench)
    if FAILURES:
        for f in FAILURES:
            print(f"ARTIFACT INVALID: {f}", file=sys.stderr)
        return 1
    print(f"artifacts OK ({seen} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
