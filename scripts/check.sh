#!/usr/bin/env bash
# Tier-1 gate in one command: import-walk smoke first (fails in seconds on
# a broken import surface), then the fast test suite.
#   ./scripts/check.sh            # fast gate (-m "not slow")
#   ./scripts/check.sh --all      # include slow multi-device/compile tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=(-m "not slow")
if [[ "${1:-}" == "--all" ]]; then
    MARK=()
    shift
fi

echo "== import-walk smoke =="
python -m pytest -x -q tests/test_import_walk.py

echo "== benchmark artifacts =="
# Torn/stale artifacts (tuning cache, BENCH_serving.json) fail here in
# milliseconds instead of poisoning later runs.
python scripts/validate_artifacts.py

echo "== calibration smoke =="
# The microbenchmark calibration pass (core/calibrate.py) must measure
# every serving-path constant on the CPU backend — fast probes, nothing
# persisted (the committed cache stays exactly as validated above).
python -m repro.launch.calibrate --fast --no-persist

# With explicit pytest args, run exactly what the caller asked for: no
# serving-subset pre-pass (it would be redundant) and no --ignore flags
# (an explicit serving path + --ignore would collect nothing and exit 5
# under set -e).
IGNORES=()
if [[ $# -eq 0 ]]; then
    echo "== serving subset =="
    # The serving stack regresses most often; surface its failures before
    # the full sweep. test_serve_chunked also gates the single-trace
    # invariant: ServingEngine.prefill_traces must stay at one executable
    # for the chunked path no matter the prompt-length mix, and
    # test_serve_spec gates the same for the speculative verify
    # executable (verify_traces == 1). test_serve_dist gates the
    # distributed engine: 8-device parity, the device-sharded page pool,
    # and the mesh-keyed tuning cache — its subprocess half needs 8 host
    # devices, hence the XLA_FLAGS (the in-process half is mesh-blind).
    # test_prefix_cache gates the sharing contract: cached admissions
    # must stream bit-identically to the uncached engine on every path.
    python -m pytest -x -q tests/test_serve.py tests/test_serve_paged.py \
        tests/test_serve_chunked.py tests/test_serve_spec.py \
        tests/test_flash_decode.py tests/test_paged_kv.py \
        tests/test_prefix_cache.py
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        python -m pytest -x -q ${MARK[@]+"${MARK[@]}"} \
        tests/test_serve_dist.py
    echo "== fault-injection smoke =="
    # The robustness contract: open-loop traffic determinism, SLO
    # admission/shedding, and the seeded fault schedule (pool squeeze,
    # accept collapse, churn storm) with bit-identical surviving streams.
    # test_telemetry gates the observability contract on top: tracing is
    # bit-identical to the untraced engine on every path (greedy,
    # sampled, spec, faults), and the event trace reconciles exactly
    # against the legacy counters and the pool's conservation law.
    # test_calibrate gates the constant-resolution layer: probes finite/
    # positive, calibrated entries preferred, torn entries fall back,
    # REPRO_DEFAULT_CONSTANTS reproduces the default decisions.
    python -m pytest -x -q tests/test_serve_faults.py tests/test_traffic.py \
        tests/test_telemetry.py tests/test_calibrate.py
    IGNORES=(--ignore=tests/test_serve.py --ignore=tests/test_serve_paged.py
             --ignore=tests/test_serve_chunked.py
             --ignore=tests/test_serve_spec.py
             --ignore=tests/test_flash_decode.py
             --ignore=tests/test_paged_kv.py
             --ignore=tests/test_prefix_cache.py
             --ignore=tests/test_serve_dist.py
             --ignore=tests/test_serve_faults.py
             --ignore=tests/test_traffic.py
             --ignore=tests/test_telemetry.py
             --ignore=tests/test_calibrate.py)
fi

echo "== test suite =="
# ${MARK[@]+...}: empty-array expansion trips `set -u` on bash < 4.4.
python -m pytest -x -q ${MARK[@]+"${MARK[@]}"} ${IGNORES[@]+"${IGNORES[@]}"} "$@"
