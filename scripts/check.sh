#!/usr/bin/env bash
# Tier-1 gate in one command: import-walk smoke first (fails in seconds on
# a broken import surface), then the fast test suite.
#   ./scripts/check.sh            # fast gate (-m "not slow")
#   ./scripts/check.sh --all      # include slow multi-device/compile tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=(-m "not slow")
if [[ "${1:-}" == "--all" ]]; then
    MARK=()
    shift
fi

echo "== import-walk smoke =="
python -m pytest -x -q tests/test_import_walk.py

echo "== test suite =="
# ${MARK[@]+...}: empty-array expansion trips `set -u` on bash < 4.4.
python -m pytest -x -q ${MARK[@]+"${MARK[@]}"} "$@"
